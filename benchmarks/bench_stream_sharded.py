"""Sharded streaming-index benchmark: per-shard write throughput,
cross-shard query latency, and compaction pause overlap.

The scaling story the sharded mutable index buys (vs the single-host
``bench_stream`` workload):

  * **per-shard write throughput** -- gid allocation is the only global
    synchronization point; routed inserts/deletes are shard-local, so
    write ops/s is reported both aggregate and per shard;
  * **cross-shard query p50/p99** -- every query batch pins an epoch
    vector and runs the two-round lambda exchange across heterogeneous
    shard states (delta-only, multi-segment, mid-compaction), served
    through a warm per-shard-invalidating lambda cache;
  * **compaction pause overlap** -- shards compact independently; the
    fraction of total compaction wall time during which >= 2 shards were
    compacting concurrently measures how much restructuring work the
    sharding hides (0 on a single-host index by construction);
  * **stacked vs sequential sweep** -- on the final (multi-segment)
    snapshot pin, the two-round exchange's round 2 run as the
    segment-parallel one-launch stacked sweep vs the sequential
    per-shard/per-segment loop: p50/p99 latency and tiles skipped
    (the stacked grid force-skips its pad/dead tiles; its per-live-tile
    cap is looser -- both counters are reported, that is the measured
    crossover ``DispatchPolicy.stacked_min_fanout`` encodes).

Run:

    PYTHONPATH=src python benchmarks/bench_stream_sharded.py
"""
from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from benchmarks.common import (live_tiles_covered, pct,
                                   quantized_probe_report,
                                   stacked_live_skip_entry, stacked_vs_seq)
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    from common import (live_tiles_covered, pct, quantized_probe_report,
                        stacked_live_skip_entry, stacked_vs_seq)

QUANT_DTYPES = ("bf16", "int8")


def overlap_stats(log):
    """From a merged compaction log (t0_s/t1_s intervals per run):
    (total compaction seconds, seconds with >= 2 shards compacting)."""
    events = []
    for c in log:
        events.append((c["t0_s"], 1))
        events.append((c["t1_s"], -1))
    events.sort()
    total = overlap = 0.0
    depth = 0
    prev = None
    for t, delta in events:
        if prev is not None and depth > 0:
            total += t - prev
            if depth >= 2:
                overlap += t - prev
        depth += delta
        prev = t
    return total, overlap


def sweep_compare(snap, queries, k, *, iters=20, probe_grid=(0, 4)):
    """Stacked vs sequential sweep over one pinned (multi-segment)
    snapshot: p50/p99 per query batch + tiles skipped per batch, for the
    sequential exchange, the single-pass stacked round 2 (the PR-4
    schedule, ``probe_tiles=0``) and the two-pass program at each probe
    width plus the library default."""
    from repro.core.balltree import normalize_query

    qn = normalize_query(queries).astype(np.float32)
    mode_kw = {"seq": {"stacked": False}}
    for p in probe_grid:
        mode_kw[f"stacked_p{p}"] = {"stacked": True, "probe_tiles": p}
    mode_kw["stacked"] = {"stacked": True, "probe_tiles": None}
    for dt in QUANT_DTYPES:  # quantized round-2 probe, default width
        mode_kw[f"stacked_{dt}"] = {"stacked": True, "probe_tiles": None,
                                    "probe_dtype": dt}
    modes = stacked_vs_seq(
        lambda **kw: snap.query(qn, k, return_counters=True, **kw)[2],
        modes=mode_kw, iters=iters)
    out = {"sweep_fanout": sum(1 for seg in snap.segments if seg.live)}
    for mode, r in modes.items():
        out[f"{mode}_sweep_p50_ms"] = r["p50_ms"]
        out[f"{mode}_sweep_p99_ms"] = r["p99_ms"]
        out[f"{mode}_tiles_skipped"] = r["tiles_skipped"]
    out["stacked_speedup_p50"] = (out["seq_sweep_p50_ms"]
                                  / max(out["stacked_sweep_p50_ms"], 1e-9))
    out["probe_speedup_p50"] = (out["stacked_p0_sweep_p50_ms"]
                                / max(out["stacked_sweep_p50_ms"], 1e-9))
    return out


def round2_skip_profile(snap, queries, k, *, probe_grid=(0, 4, None)):
    """Live-tile skip accounting for round 2 of the exchange at
    per-query granularity (bq=1), under the same ``lambda0``: the
    sequential per-shard loop vs the two-pass stacked program at each
    probe width.  This is the acceptance comparison -- the probe pass
    must restore (or beat) the sequential path's live-tile pruning."""
    import jax.numpy as jnp

    from repro.core.balltree import normalize_query
    from repro.kernels.stacked_sweep import concat_cached

    qn = normalize_query(queries).astype(np.float32)
    _, _, info = snap.query(qn, k, return_info=True, stacked=False)
    lam0 = jnp.asarray(info["lambda0"], jnp.float32)
    covered = live_tiles_covered(snap.segments, qn.shape[0])
    seq = 0
    for sh in snap.shards:
        if not sh.segments:
            continue
        _, _, cnt = sh.query(qn, k, lambda_cap=lam0,
                             include_deltas=False, stacked=False,
                             return_counters=True)
        seq += int(np.asarray(cnt)[7])
    out = {"seq": {"live_skips": seq, "live_covered": covered,
                   "skip_frac": seq / max(1, covered)}}
    comb = concat_cached([sh.stacked_leaves() for sh in snap.shards
                          if sh.segments])
    for p in probe_grid:
        name = "stacked" if p is None else f"stacked_p{p}"
        out[name] = stacked_live_skip_entry(
            comb, qn, k, cap=lam0, probe=p, covered=covered,
            is_bc=snap.variant == "bc")
    for dt in QUANT_DTYPES:
        out[f"stacked_{dt}"] = stacked_live_skip_entry(
            comb, qn, k, cap=lam0, probe=None, covered=covered,
            is_bc=snap.variant == "bc", probe_dtype=dt)
    return out


def run_sharded_stream(args):
    from repro.core import exact_search
    from repro.core.balltree import normalize_query
    from repro.serve import DispatchPolicy, P2HEngine
    from repro.stream import CompactionPolicy, ShardedMutableP2HIndex

    import jax.numpy as jnp

    from repro.data import make_p2h_dataset

    rng = np.random.default_rng(args.seed)
    # one generator call covers the seed set, the insert stream and the
    # hot queries, so streamed-in points follow the same distribution as
    # the bulk load (kind="planted" is the low-intrinsic-dim config
    # where the tree's pruning -- and hence live-skip fractions -- are
    # actually exercised; rng.normal here used to read as skip_frac ~ 0)
    pool, hot = make_p2h_dataset(args.n + args.ops, args.d,
                                 kind=args.kind, n_queries=4,
                                 seed=args.seed)
    data, insert_pool = pool[:args.n], pool[args.n:]
    policy = CompactionPolicy(delta_capacity=args.delta_capacity)
    m = ShardedMutableP2HIndex.from_data(
        data, args.shards, n0=args.n0, policy=policy,
        background=args.background)
    eng = P2HEngine(m, slot_size=8,
                    policy=DispatchPolicy(prefer_pallas=False))

    live = list(range(args.n))

    # warmup: compile the serving programs (engine route, stacked
    # round 2, delta scan) before the timed loop -- steady state is the
    # metric, and the shape-bucketed compile cache + the compactor's
    # pre-publish warmup keep mid-run republishes on already-compiled
    # programs thereafter.  Stats are reset so compile_count/cache_hit
    # report the *timed* window only (the fence wants zero query-path
    # compiles there).
    from repro.kernels.stacked_sweep import (reset_stacked_compile_stats,
                                             stacked_compile_stats)
    warm_trace = np.stack([hot[i % len(hot)] for i in range(8)])
    for _ in range(3):
        eng.query(warm_trace, k=args.k)
    m.wait_compaction()
    reset_stacked_compile_stats()
    eng.reset_stats()

    ins_lat, del_lat, q_lat = [], [], []
    per_shard_writes = np.zeros((args.shards,), np.int64)
    ins_i = 0
    t_all = time.perf_counter()
    for step in range(args.ops):
        r = rng.random()
        if r < 0.55:
            x = insert_pool[ins_i % len(insert_pool)]
            ins_i += 1
            t0 = time.perf_counter()
            gid = m.insert(x)
            ins_lat.append(time.perf_counter() - t0)
            per_shard_writes[m.router.shard_of(gid)] += 1
            live.append(gid)
        elif r < 0.8 and live:
            gid = live.pop(int(rng.integers(len(live))))
            t0 = time.perf_counter()
            m.delete(gid)
            del_lat.append(time.perf_counter() - t0)
            per_shard_writes[m.router.shard_of(gid)] += 1
        else:
            trace = np.stack([hot[i % len(hot)] for i in range(8)])
            t0 = time.perf_counter()
            eng.query(trace, k=args.k)
            q_lat.append(time.perf_counter() - t0)
    m.wait_compaction()
    wall = time.perf_counter() - t_all
    # query-path compile accounting over the timed window (the CI fence
    # reads these: a retrace spike in the timed loop shows up here long
    # before it shows up in a smoke config's noisy percentiles)
    cst = stacked_compile_stats()
    admission = m.admission_stats()

    # exactness spot-check on the final live set
    snap = m.snapshot()
    bd, bi = m.query(hot, k=args.k)
    X, _ = snap.live_points()
    ed, _ = exact_search(jnp.asarray(X),
                         jnp.asarray(normalize_query(hot)), k=args.k)
    assert np.allclose(bd, np.asarray(ed), rtol=1e-4, atol=1e-5), \
        "sharded stream results diverged from the brute-force oracle"

    # stacked vs sequential sweep on the final multi-segment pin
    sweep = sweep_compare(snap, hot, args.k)
    skip_profile = round2_skip_profile(snap, hot, args.k)

    # quantized round-2 probe: bit-exactness vs the f32 launch, the
    # bytes/tile roofline, and the skip/p50 deltas of the precision
    # trade (see benchmarks.common.quantized_probe_report)
    qn_hot = normalize_query(hot).astype(np.float32)
    stk0 = next(sh.stacked_leaves() for sh in snap.shards if sh.segments)
    quantized = quantized_probe_report(
        lambda dt: snap.query(qn_hot, args.k, stacked=True,
                              probe_dtype=dt),
        n0=stk0.n0, d=stk0.d)
    quantized["p50_delta_ms"] = {
        dt: (sweep[f"stacked_{dt}_sweep_p50_ms"]
             - sweep["stacked_sweep_p50_ms"]) for dt in QUANT_DTYPES}
    quantized["skip_delta"] = {
        dt: (skip_profile[f"stacked_{dt}"]["live_skips"]
             - skip_profile["stacked"]["live_skips"])
        for dt in QUANT_DTYPES}
    assert quantized["quantized_exact"], \
        "quantized round-2 probe must stay bit-exact vs the f32 launch"

    log = m.compaction_log
    pauses = [c["wall_s"] for c in log]
    compact_total, compact_overlap = overlap_stats(log)
    shard_tp = per_shard_writes / max(wall, 1e-9)
    res = {
        **sweep,
        "skip_profile": skip_profile,
        "quantized": quantized,
        "shards": args.shards,
        "ops": args.ops,
        "wall_s": wall,
        "inserts": len(ins_lat),
        "deletes": len(del_lat),
        "query_batches": len(q_lat),
        "insert_p50_us": pct(ins_lat, 50) * 1e6,
        "insert_p99_us": pct(ins_lat, 99) * 1e6,
        "delete_p50_us": pct(del_lat, 50) * 1e6,
        "delete_p99_us": pct(del_lat, 99) * 1e6,
        "query_p50_ms": pct(q_lat, 50) * 1e3,
        "query_p99_ms": pct(q_lat, 99) * 1e3,
        "write_ops_per_s": (len(ins_lat) + len(del_lat)) / max(wall, 1e-9),
        "shard_write_ops_per_s_min": float(shard_tp.min()),
        "shard_write_ops_per_s_max": float(shard_tp.max()),
        "compactions": len(pauses),
        "compact_p50_ms": pct(pauses, 50) * 1e3,
        "compact_max_ms": (max(pauses) * 1e3) if pauses else float("nan"),
        "compact_total_s": compact_total,
        "compact_overlap_s": compact_overlap,
        "compact_overlap_frac": (compact_overlap / compact_total
                                 if compact_total else 0.0),
        "final_live": m.live_count,
        "epoch": m.epoch,
        "segments": len(snap.segments),
        "lambda_cache": eng.cache.stats(),
        "compile_count": cst["compile_count"],
        "cache_hit": cst["cache_hit"],
        "warm_compiles": cst["warm_compiles"],
        "query_misses": cst["misses"],
        "recent_misses": [list(s) for s in cst["recent_misses"]],
        "admission": admission,
        # uniform degradation surface: routed-write correctness
        # (misroutes must stay 0) + the resilience counter block
        # BENCH_resilience.json fences -- all-zero here, no faults
        "misroutes": m.misroutes,
        "resilience": eng.stats()["resilience"],
    }
    m.close()
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--n0", type=int, default=64)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--ops", type=int, default=2000)
    ap.add_argument("--delta-capacity", type=int, default=256)
    ap.add_argument("--kind", default="planted",
                    choices=["normal", "clustered", "planted", "unit",
                             "heavy"],
                    help="data distribution (default: planted clusters "
                         "in a low-dim latent subspace, where the tree "
                         "actually prunes)")
    ap.add_argument("--background", action="store_true", default=True)
    ap.add_argument("--no-background", dest="background",
                    action="store_false")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)

    res = run_sharded_stream(args)
    print(f"workload: {res['inserts']} inserts, {res['deletes']} deletes, "
          f"{res['query_batches']} query batches over {res['shards']} "
          f"shards in {res['wall_s']:.2f}s "
          f"-> {res['write_ops_per_s']:.0f} write ops/s "
          f"(per shard {res['shard_write_ops_per_s_min']:.0f}.."
          f"{res['shard_write_ops_per_s_max']:.0f})")
    print(f"insert p50 {res['insert_p50_us']:.0f} us  "
          f"p99 {res['insert_p99_us']:.0f} us   "
          f"delete p50 {res['delete_p50_us']:.0f} us  "
          f"p99 {res['delete_p99_us']:.0f} us")
    print(f"cross-shard query p50 {res['query_p50_ms']:.1f} ms  "
          f"p99 {res['query_p99_ms']:.1f} ms (two-round exchange, warm "
          f"per-shard cache: {res['lambda_cache']})")
    print(f"timed-window compiles: {res['query_misses']} query-path, "
          f"{res['warm_compiles']} pre-publish warm, "
          f"{res['cache_hit']} cache hits; admission {res['admission']}")
    if res["recent_misses"]:
        print(f"  query-path miss signatures: {res['recent_misses']}")
    print(f"compactions: {res['compactions']} "
          f"(p50 {res['compact_p50_ms']:.1f} ms, "
          f"max {res['compact_max_ms']:.1f} ms, "
          f"overlap {res['compact_overlap_frac']:.0%} of "
          f"{res['compact_total_s']*1e3:.0f} ms total); "
          f"final: {res['final_live']} live in {res['segments']} segments, "
          f"epoch vector {res['epoch']}")
    print(f"sweep @ fan-out {res['sweep_fanout']}: sequential "
          f"p50 {res['seq_sweep_p50_ms']:.1f} ms "
          f"p99 {res['seq_sweep_p99_ms']:.1f} ms "
          f"({res['seq_tiles_skipped']} tiles skipped)  |  single-pass "
          f"stacked (PR-4) p50 {res['stacked_p0_sweep_p50_ms']:.1f} ms  "
          f"|  two-pass stacked p50 {res['stacked_sweep_p50_ms']:.1f} ms "
          f"p99 {res['stacked_sweep_p99_ms']:.1f} ms "
          f"({res['stacked_tiles_skipped']} tiles skipped, incl. forced "
          f"pad/dead-tile skips)  ->  {res['stacked_speedup_p50']:.2f}x "
          f"p50 vs sequential, {res['probe_speedup_p50']:.2f}x vs "
          "single-pass")
    prof = res["skip_profile"]
    print("round-2 live-tile skip fractions under lambda0: "
          + "  ".join(f"{m}={r['skip_frac']:.3f}" for m, r in prof.items())
          + f"; probe overhead {prof['stacked']['probe']}")
    quant = res["quantized"]
    print("quantized round-2 probe: exact="
          + str(quant["quantized_exact"]) + "  " + "  ".join(
              f"{dt}: {quant['bytes_tile_reduction'][dt]:.2f}x bytes/tile "
              f"p50{quant['p50_delta_ms'][dt]:+.2f}ms "
              f"skips{quant['skip_delta'][dt]:+d}"
              for dt in quant["bytes_tile_reduction"]))
    return res


def run(csv, *, smoke: bool = False) -> dict:
    """benchmarks.run registry entry point: CSV rows for bench_output
    plus the returned dict ``benchmarks.run`` serializes to
    ``BENCH_stream_sharded.json``.  ``smoke=True`` shrinks the workload
    to a CI-sized config (same shape, same JSON schema)."""
    res = main(["--n", "2000", "--ops", "150", "--shards", "4",
                "--delta-capacity", "24"] if smoke else
               ["--n", "8000", "--ops", "600", "--shards", "4",
                "--delta-capacity", "48"])
    csv("stream_sharded,metric,value")
    for key in ("shards", "write_ops_per_s", "shard_write_ops_per_s_min",
                "shard_write_ops_per_s_max", "insert_p50_us",
                "insert_p99_us", "delete_p50_us", "delete_p99_us",
                "query_p50_ms", "query_p99_ms", "compactions",
                "compact_p50_ms", "compact_max_ms", "compact_overlap_frac",
                "final_live", "segments", "sweep_fanout",
                "seq_sweep_p50_ms", "seq_sweep_p99_ms",
                "seq_tiles_skipped", "stacked_p0_sweep_p50_ms",
                "stacked_sweep_p50_ms",
                "stacked_sweep_p99_ms", "stacked_tiles_skipped",
                "stacked_speedup_p50", "probe_speedup_p50",
                "compile_count", "cache_hit"):
        csv(f"stream_sharded,{key},{res[key]:.3f}"
            if isinstance(res[key], float)
            else f"stream_sharded,{key},{res[key]}")
    csv("stream_sharded_skips,mode,live_skips,live_covered,skip_frac")
    for mode, r in res["skip_profile"].items():
        csv(f"stream_sharded_skips,{mode},{r['live_skips']},"
            f"{r['live_covered']},{r['skip_frac']:.4f}")
    quant = res["quantized"]
    csv("stream_sharded_quantized,dtype,exact,bytes_per_tile,"
        "bytes_reduction,p50_delta_ms,skip_delta")
    for dt in quant["exact"]:
        csv(f"stream_sharded_quantized,{dt},{quant['exact'][dt]},"
            f"{quant['bytes_per_tile'][dt]},"
            f"{quant['bytes_tile_reduction'][dt]:.3f},"
            f"{quant['p50_delta_ms'][dt]:.3f},{quant['skip_delta'][dt]}")
    return res


if __name__ == "__main__":
    main()
