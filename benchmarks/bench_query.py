"""Paper Figures 5/6/9: query time vs recall (candidate-fraction sweep for
the trees, probe-budget sweep for NH/FH), and sensitivity to k."""
from __future__ import annotations

from repro.core.api import P2HIndex
from repro.core.fh import FHIndex
from repro.core.nh import NHIndex

from benchmarks.common import DATASETS, ground_truth, load, recall, timeit


def run(csv):
    for name in list(DATASETS)[:3]:
        x, q = load(name)
        d = x.shape[1]
        for k in (1, 10):
            gtd, gti = ground_truth(x, q, k)
            bc = P2HIndex.build(x, n0=128, variant="bc")
            for frac in (0.01, 0.05, 0.2, 1.0):
                t, (bd, bi) = timeit(bc.query, q, k, method="beam",
                                     frac=frac, normalize=False)
                csv(f"query,{name},bc-tree(frac={frac}),k={k},"
                    f"{t/len(q)*1e3:.3f}ms,recall={recall(bi, gti):.3f}")
            t, (bd, bi) = timeit(bc.query, q, k, method="dfs",
                                 normalize=False)
            csv(f"query,{name},bc-tree(dfs-exact),k={k},"
                f"{t/len(q)*1e3:.3f}ms,recall={recall(bi, gti):.3f}")
            nh = NHIndex.build(x, m=16, lam=4 * d)
            fh = FHIndex.build(x, m=16, lam=4 * d)
            for budget in (256, 2048):
                _, (nd, ni, _) = timeit(nh.query, q, k, budget=budget,
                                        normalize=False)
                t_nh, _ = timeit(nh.query, q, k, budget=budget,
                                 normalize=False)
                csv(f"query,{name},nh(budget={budget}),k={k},"
                    f"{t_nh/len(q)*1e3:.3f}ms,recall={recall(ni, gti):.3f}")
                t_fh, (fd, fi, _) = timeit(fh.query, q, k, budget=budget,
                                           normalize=False)
                csv(f"query,{name},fh(budget={budget}),k={k},"
                    f"{t_fh/len(q)*1e3:.3f}ms,recall={recall(fi, gti):.3f}")
