"""Multi-device serving-mesh benchmark: the stacked sweep + lambda
exchange sharded across 1 / 2 / 4 devices.

Device count is a process-level property (``XLA_FLAGS=--xla_force_host_
platform_device_count`` must be set before the first jax import), so
the driver forks one child per device count; each child builds the same
multi-segment sharded workload, fences the mesh placement **bit-exact**
against the single-device launch on its own snapshot (a bench that is
not exact has no speedup to report), then times the stacked cross-shard
query path and emits one JSON line the parent aggregates into
``BENCH_mesh.json``:

  * ``devices_{1,2,4}.qps / p50_ms / p99_ms`` -- the scaling curve;
  * ``devices_*.exact`` -- the per-child parity fence result;
  * ``qps_monotone`` -- whether qps is non-decreasing in device count
    (the simulated-host curve CI watches; real accelerator meshes are
    the production claim).

Run:

    PYTHONPATH=src python benchmarks/bench_mesh.py
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_RESULT_TAG = "MESH_RESULT "
_DEVICE_COUNTS = (1, 2, 4)


def _child(devices: int, smoke: bool) -> None:
    """Runs inside the forked process (device count already forced)."""
    import numpy as np

    import jax

    assert jax.device_count() >= devices, (jax.device_count(), devices)
    from repro.core.balltree import normalize_query
    from repro.launch.mesh import make_serving_mesh
    from repro.stream.compaction import CompactionPolicy
    from repro.stream.sharded import ShardedMutableP2HIndex

    dim, k = 16, 10
    n = 6000 if smoke else 12000
    nq = 16
    iters = 12 if smoke else 50
    rng = np.random.default_rng(0)
    idx = ShardedMutableP2HIndex.from_data(
        rng.normal(size=(n, dim)).astype(np.float32), 2, n0=64,
        policy=CompactionPolicy(delta_capacity=128, max_segments=16))
    idx.compact(force=True)
    # widen the segment fan-out (the sharded axis) with auto-sealed
    # batches, leaving a small live delta tail -- the serving-shaped
    # mix the mesh shards; below ~8 segments of ~1k rows the launch is
    # host-overhead-bound and the simulated curve measures nothing
    for _ in range(8):
        idx.insert_batch(
            rng.normal(size=(n // 8, dim)).astype(np.float32))
    qn = normalize_query(
        rng.normal(size=(nq, dim + 1))).astype(np.float32)

    mesh = make_serving_mesh(devices) if devices > 1 else None
    if mesh is not None:
        idx.set_mesh(mesh)
    snap = idx.snapshot()

    # exactness fence before any timing: the mesh placement must return
    # the single-device launch's answer bit-for-bit on this snapshot
    import dataclasses

    base = dataclasses.replace(snap, mesh=None)
    bd0, bi0 = base.query(qn, k, method="stacked")
    bd1, bi1 = snap.query(qn, k, method="stacked")
    exact = bool(np.array_equal(np.asarray(bd0), np.asarray(bd1))
                 and np.array_equal(np.asarray(bi0), np.asarray(bi1)))

    for _ in range(3):  # warm the jit cache out of the timed loop
        snap.query(qn, k, method="stacked")
    lat = []
    t0 = time.perf_counter()
    for _ in range(iters):
        t = time.perf_counter()
        snap.query(qn, k, method="stacked")
        lat.append(time.perf_counter() - t)
    total = time.perf_counter() - t0
    lat.sort()

    def pct(p):
        return lat[min(len(lat) - 1,
                       int(round(p / 100 * (len(lat) - 1))))] * 1e3

    idx.close()
    print(_RESULT_TAG + json.dumps({
        "devices": devices,
        "exact": exact,
        "qps": nq * iters / total,
        "p50_ms": pct(50),
        "p99_ms": pct(99),
        "fanout": len(snap.segments),
        "live": int(snap.live_count),
    }), flush=True)


def _spawn(devices: int, smoke: bool) -> dict:
    env = dict(os.environ)
    # single-threaded per-device compute: forced host devices share one
    # machine, so without this the 1-device baseline already consumes
    # every core and the curve only measures collective overhead.  With
    # it, device-parallelism is the only parallelism -- the honest
    # simulated-scaling methodology (and the same flag every child
    # gets, so the comparison is like-for-like).
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        "--xla_cpu_multi_thread_eigen=false")
    env["OPENBLAS_NUM_THREADS"] = "1"
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    extra = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + (os.pathsep + extra if extra else "")
    cmd = [sys.executable, os.path.abspath(__file__), "--child",
           "--devices", str(devices)] + (["--smoke"] if smoke else [])
    res = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=1200)
    if res.returncode != 0:
        raise RuntimeError(
            f"bench_mesh child (devices={devices}) failed:\n"
            + res.stderr[-4000:])
    for line in reversed(res.stdout.splitlines()):
        if line.startswith(_RESULT_TAG):
            return json.loads(line[len(_RESULT_TAG):])
    raise RuntimeError(f"bench_mesh child (devices={devices}) emitted "
                       "no result line")


def run_mesh(smoke: bool = False) -> dict:
    out: dict = {"device_counts": list(_DEVICE_COUNTS)}
    qps = []
    for devices in _DEVICE_COUNTS:
        r = _spawn(devices, smoke)
        assert r["exact"], \
            f"mesh placement diverged at devices={devices}"
        out[f"devices_{devices}"] = r
        qps.append(r["qps"])
    out["qps_monotone"] = bool(
        all(b >= a * 0.95 for a, b in zip(qps, qps[1:])))
    return out


def run(csv, *, smoke: bool = False) -> dict:
    """benchmarks.run registry entry point; the returned dict becomes
    ``BENCH_mesh.json``."""
    res = run_mesh(smoke=smoke)
    csv("mesh,devices,qps,p50_ms,p99_ms,fanout,exact")
    for devices in _DEVICE_COUNTS:
        r = res[f"devices_{devices}"]
        csv(f"mesh,{devices},{r['qps']:.1f},{r['p50_ms']:.3f},"
            f"{r['p99_ms']:.3f},{r['fanout']},{int(r['exact'])}")
    csv(f"mesh,qps_monotone,{int(res['qps_monotone'])},,,,")
    return res


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    if args.child:
        _child(args.devices, args.smoke)
        return
    res = run_mesh(smoke=args.smoke)
    print(json.dumps(res, indent=1, sort_keys=True))


if __name__ == "__main__":
    main()
