"""Beyond-paper: the two-round lambda-exchange distributed index.

Measures the round-2 pruning win (tiles skipped with the global lambda cap
vs without) on a sharded index -- the distributed optimization described in
repro/core/distributed.py.  Runs on 1 device (mesh (1,)) in-process; the
8-device behaviour is covered by tests/test_distributed.py subprocesses.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.api import P2HIndex
from repro.core.search import SearchStats, sweep_search

from benchmarks.common import ground_truth, load, timeit


def run(csv):
    x, q = load("Synth-Cluster")
    qj = jnp.asarray(q)
    k = 10
    idx = P2HIndex.build(x, n0=128, variant="bc")
    # emulate the exchange: round-1 on a 2% prefix gives lambda0
    bd1, _, _ = sweep_search(idx.tree, qj, k, frac=0.02)
    lam0 = bd1[:, k - 1]
    _, (bd, bi, cnt) = timeit(sweep_search, idx.tree, qj, k)
    st_plain = SearchStats(cnt)
    _, (bd2, bi2, cnt2) = timeit(sweep_search, idx.tree, qj, k,
                                 lambda_cap=lam0)
    st_cap = SearchStats(cnt2)
    ed, _ = ground_truth(x, q, k)
    ok = np.allclose(np.asarray(bd2), ed, atol=1e-5)
    csv(f"distributed,lambda_exchange,exact={ok},"
        f"tiles_skipped {st_plain['tiles_skipped']} -> {st_cap['tiles_skipped']},"
        f"verified {st_plain['verified']} -> {st_cap['verified']}")
