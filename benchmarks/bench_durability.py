"""Kill-and-recover chaos harness for the durability subsystem.

The WAL's contract (``repro.stream.wal``) is *recovery to the last
acknowledged write*: an op whose ack token came back from the group
commit must survive a SIGKILL; anything later may be lost.  This lane
measures and enforces exactly that, end to end, with a real process
kill -- not a mocked crash:

  * a **child process** (``--child`` mode of this module) opens a
    durable sharded index (``ShardedMutableP2HIndex.open``) and runs an
    endless mixed insert/delete storm.  Its ``on_ack`` callback appends
    one line per acknowledged op to ``acked.log`` (line-buffered: the
    bytes land in the OS page cache, which survives SIGKILL) plus the
    current epoch vector; delete *attempts* are logged before they are
    issued (an unacked-but-durable delete legally removes an acked
    insert -- per-shard log-prefix semantics -- so the checker must
    know about it).  Periodic checkpoints exercise the
    checkpoint-plus-tail recovery path and WAL prefix truncation.
  * the **parent** arms a :class:`repro.runtime.StepWatchdog` whose
    ``on_expire`` SIGKILLs the child, beats it until the storm has done
    enough acknowledged work, then lets it fire mid-storm.  Recovery
    (``ShardedMutableP2HIndex.open`` again) runs under
    :func:`repro.runtime.run_with_restarts` -- the supervisor loop a
    real deployment would use -- and is timed.
  * the parent then checks the recovered index against the ack log:
    every acked insert not covered by a delete attempt is live, no
    acked delete resurrects, no gid is owned by two shards, and the
    recovered epoch vector is componentwise >= the last acked vector.

Several kill rounds run back to back **against the same directory** --
each round's child resumes from the previous round's recovered state,
so recovery-of-a-recovery (double restore, truncated logs, grown id
space) is exercised for free.  ``run`` returns the JSON trajectory dict
(``BENCH_durability.json``): replay throughput, recovery p50/max, and
the three invariant counters CI fences at zero
(``tools/check_bench_json.py``).
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import pct

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_ACK_LOG = "acked.log"


# ----------------------------------------------------------------------
# child: the write storm (runs in its own process; killed by the parent)
# ----------------------------------------------------------------------
def _child_main(args) -> None:
    from repro.stream.sharded import ShardedMutableP2HIndex
    from repro.stream.wal import WalConfig

    rng = np.random.default_rng(args.seed)
    state = {"idx": None}
    ack_fh = open(os.path.join(args.dir, _ACK_LOG), "a", buffering=1)

    def on_ack(tokens):
        # line-buffered: each line hits the OS page cache on the
        # newline, so it survives the parent's SIGKILL exactly like the
        # fsync'd WAL bytes it mirrors
        for kind, gid in tokens:
            ack_fh.write(f"{kind} {gid}\n")
        if state["idx"] is not None:
            ep = " ".join(str(e) for e in state["idx"].epoch)
            ack_fh.write(f"E {ep}\n")

    idx = ShardedMutableP2HIndex.open(
        args.dir, dim=args.dim, num_shards=args.shards,
        wal_config=WalConfig(fsync_every_n=args.fsync_every_n,
                             fsync_interval_ms=5.0),
        on_ack=on_ack)
    state["idx"] = idx

    issued: list[int] = []  # gids this incarnation inserted
    it = 0
    while True:  # until SIGKILL
        pts = rng.normal(size=(args.batch, args.dim)).astype(np.float32)
        issued += [int(g) for g in idx.insert_batch(pts)]
        if issued and rng.random() < 0.4:
            gid = issued.pop(int(rng.integers(len(issued))))
            # attempt line *before* the op: its WAL record may become
            # durable without the ack ever coming back
            ack_fh.write(f"d? {gid}\n")
            idx.delete(gid)
        it += 1
        if args.save_every and it % args.save_every == 0:
            idx.save(args.dir)  # checkpoint + WAL prefix truncation


# ----------------------------------------------------------------------
# parent: kill, recover, verify
# ----------------------------------------------------------------------
def _read_ack_log(path: str):
    """Parse the child's ack log: acked inserts/deletes, delete
    attempts, and the last *complete* epoch-vector line (a final line
    the kill tore mid-write is ignored -- its op was not acked from the
    checker's point of view either)."""
    acked_ins, acked_del, attempted = set(), set(), set()
    last_epochs = None
    if not os.path.exists(path):
        return acked_ins, acked_del, attempted, last_epochs
    with open(path, "rb") as fh:
        data = fh.read()
    lines = data.split(b"\n")
    if lines and lines[-1] != b"":
        lines = lines[:-1]  # torn final line (no newline): never acked
    for raw in lines:
        parts = raw.decode("utf-8", "replace").split()
        if not parts:
            continue
        if parts[0] == "ins":
            acked_ins.add(int(parts[1]))
        elif parts[0] == "del":
            acked_del.add(int(parts[1]))
        elif parts[0] == "d?":
            attempted.add(int(parts[1]))
        elif parts[0] == "E":
            last_epochs = tuple(int(e) for e in parts[1:])
    return acked_ins, acked_del, attempted, last_epochs


def _count_ack_lines(path: str) -> int:
    if not os.path.exists(path):
        return 0
    with open(path, "rb") as fh:
        return fh.read().count(b"\n")


def _wal_tail_ops(wal_dir: str) -> int:
    """Records currently in the WAL tails (what recovery will replay)."""
    from repro.stream.wal import ShardWal

    n = 0
    if not os.path.isdir(wal_dir):
        return 0
    for name in sorted(os.listdir(wal_dir)):
        if not name.endswith(".wal"):
            continue
        wal = ShardWal(os.path.join(wal_dir, name))
        n += sum(1 for _ in wal.records(0))
        wal.close()
    return n


def _kill_round(directory: str, *, dim: int, shards: int, seed: int,
                min_acks: int, kill_after_s: float, save_every: int,
                fsync_every_n: int, spawn_timeout_s: float = 180.0) -> dict:
    """One chaos round: storm, SIGKILL mid-storm, recover, verify."""
    from repro.runtime import RetryPolicy, StepWatchdog, run_with_restarts
    from repro.stream.sharded import ShardedMutableP2HIndex

    ack_path = os.path.join(directory, _ACK_LOG)
    baseline_lines = _count_ack_lines(ack_path)
    env = dict(os.environ)
    # the child runs this file as a script: it needs src/ (repro) and
    # the repo root (the benchmarks package itself) on its path
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO_ROOT, "src"), _REPO_ROOT]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "--child",
         "--dir", directory, "--dim", str(dim), "--shards", str(shards),
         "--seed", str(seed), "--save-every", str(save_every),
         "--fsync-every-n", str(fsync_every_n)],
        env=env, cwd=_REPO_ROOT,
        stdout=subprocess.DEVNULL, stderr=subprocess.PIPE)
    # the watchdog IS the kill switch: beat it while the storm warms up
    # (imports, recovery of the previous round's state), stop beating
    # once enough acked work has accumulated, and its expiry SIGKILLs
    # the child mid-storm
    wd = StepWatchdog(kill_after_s, on_expire=proc.kill)
    wd.beat()
    t0 = time.monotonic()
    while (_count_ack_lines(ack_path) - baseline_lines < min_acks
           and proc.poll() is None
           and time.monotonic() - t0 < spawn_timeout_s):
        wd.beat()
        time.sleep(0.05)
    if proc.poll() is not None:  # died on its own: a bug, not a kill
        wd.stop()
        err = proc.stderr.read().decode("utf-8", "replace")
        raise RuntimeError(f"storm child exited rc={proc.returncode} "
                           f"before the kill: {err[-2000:]}")
    proc.wait()  # the watchdog's SIGKILL lands within kill_after_s
    wd.stop()
    proc.stderr.close()
    assert proc.returncode < 0, \
        f"child must die by signal, not rc={proc.returncode}"

    acked_ins, acked_del, attempted, last_epochs = _read_ack_log(ack_path)
    tail_ops = _wal_tail_ops(os.path.join(directory, "wal"))

    # recovery under the real supervisor loop: an IOError (torn
    # checkpoint leaf, unreadable log) would retry per the policy
    t0 = time.monotonic()
    idx, restarts = run_with_restarts(
        lambda: ShardedMutableP2HIndex.open(directory, dim=dim,
                                            num_shards=shards),
        lambda ix: ix, policy=RetryPolicy(max_restarts=2))
    recovery_s = time.monotonic() - t0

    per_shard = [set(int(g) for g in sh.live_gids()) for sh in idx.shards]
    live: set = set().union(*per_shard) if per_shard else set()
    dup_gids = sum(len(s) for s in per_shard) - len(live)
    # an acked insert may only be missing if a delete was *attempted*
    # on it (acked or not: the attempt's record can be durable without
    # its ack) -- anything else is lost acknowledged data
    lost = acked_ins - attempted - live
    resurrected = live & acked_del
    epochs = tuple(idx.epoch)
    epoch_regressions = 0
    if last_epochs is not None:
        epoch_regressions = sum(
            1 for a, b in zip(last_epochs, epochs) if b < a)
    # sanity: the recovered index serves queries over the survivors
    if live:
        q = np.zeros((1, dim + 1), np.float32)
        q[0, 0] = 1.0
        _, ids = idx.query(q, k=min(4, len(live)))
        ids = np.asarray(ids).ravel()
        assert np.all(np.isin(ids[ids >= 0], sorted(live)))
    misroutes = idx.stats()["misroutes"]
    idx.close()
    return {
        "acked_ops": len(acked_ins) + len(acked_del),
        "tail_ops": tail_ops,
        "recovery_s": recovery_s,
        "restarts": restarts,
        "acked_loss": len(lost),
        "dup_gids": dup_gids,
        "resurrected": len(resurrected),
        "epoch_regressions": epoch_regressions,
        "live_count": len(live),
        "misroutes": misroutes,
    }


def run(csv, smoke: bool = False) -> dict:
    """CSV rows per kill round + the BENCH_durability.json dict."""
    import tempfile

    rounds = 2 if smoke else 4
    min_acks = 40 if smoke else 300
    dim = 8 if smoke else 16
    with tempfile.TemporaryDirectory(prefix="p2h_chaos_") as directory:
        csv("durability,round,acked_ops,tail_ops,recovery_s,acked_loss,"
            "dup_gids,resurrected,epoch_regressions,live,misroutes")
        results = []
        for r in range(rounds):
            res = _kill_round(
                directory, dim=dim, shards=2, seed=1234 + r,
                min_acks=min_acks, kill_after_s=0.25,
                # checkpoint on even rounds so both recovery paths
                # (pure-WAL and checkpoint+tail) are exercised
                save_every=((5 if smoke else 20) if r % 2 == 0 else 0),
                fsync_every_n=4)
            results.append(res)
            csv(f"durability,{r},{res['acked_ops']},{res['tail_ops']},"
                f"{res['recovery_s']:.3f},{res['acked_loss']},"
                f"{res['dup_gids']},{res['resurrected']},"
                f"{res['epoch_regressions']},{res['live_count']},"
                f"{res['misroutes']}")
    rec = [r["recovery_s"] for r in results]
    replayed = sum(r["tail_ops"] for r in results)
    return {
        "rounds": rounds,
        "shards": 2,
        "acked_ops": sum(r["acked_ops"] for r in results),
        "replay_ops_per_s": replayed / max(sum(rec), 1e-9),
        "recovery_p50_s": pct(rec, 50),
        "recovery_max_s": max(rec),
        "restarts": sum(r["restarts"] for r in results),
        # the invariants; CI fences these at zero
        "acked_loss": sum(r["acked_loss"] for r in results),
        "dup_gids": sum(r["dup_gids"] for r in results),
        "epoch_regressions": sum(r["epoch_regressions"]
                                 for r in results),
    }


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true",
                    help="internal: run the write storm (killed by the "
                         "parent; never returns)")
    ap.add_argument("--dir", default=None)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--save-every", type=int, default=0,
                    help="checkpoint every N storm iterations (0: never)")
    ap.add_argument("--fsync-every-n", type=int, default=4)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    if args.child:
        assert args.dir, "--child requires --dir"
        _child_main(args)
        return
    res = run(print, smoke=args.smoke)
    import json

    print(json.dumps(res, indent=1, sort_keys=True))


if __name__ == "__main__":
    main()
