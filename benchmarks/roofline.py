"""Roofline table builder (deliverable g): reads the dry-run artifacts and
derives the three terms per (arch x shape) on the single-pod mesh.

  compute term    = metered FLOPs / peak_FLOPs          [s]
  memory term     = metered HBM bytes / HBM_bw           [s]
  collective term = metered wire bytes / link_bw         [s]

All metered quantities are PER DEVICE (XLA reports post-SPMD shapes); the
hardware constants are per chip, so the terms are directly comparable.
MODEL_FLOPS uses 6*N*D (train), 2*N*D (prefill) or 2*N_active*B (decode)
with N_active excluding embeddings and unrouted experts.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12     # TPU v5e bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link

CHIPS = 256             # single-pod mesh


def model_flops_analytic(arch, shape):
    """Useful-FLOPs estimate per device: 6ND (matmul params; MoE counts
    routed experts only; the LM head counts fully) + the PaLM-style
    attention term 2*B*S*Skv*H*Dh per attention matmul pair, halved for
    causal masks and windowed for local layers; x3 for the backward."""
    from repro.configs import SHAPES, get_config
    import numpy as np

    cfg = get_config(arch)
    sh = SHAPES[shape]
    # active (non-embedding) params
    from repro.configs import get_model
    import jax
    model, _ = get_model(arch)
    aparams, _ = model.abstract_params()
    flat = jax.tree_util.tree_flatten_with_path(aparams)[0]
    total = emb = expert = 0
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        total += n
        if name == "embed" or "pos" in name:
            emb += n
        if "ffn/w" in name and cfg.num_experts:
            expert += n
    active = total - emb
    if cfg.tie_embed:  # tied head still does the logits matmul
        active += cfg.vocab * cfg.d_model
    if cfg.num_experts:
        active -= expert * (1 - cfg.top_k / cfg.num_experts)
    B, S = sh["batch"], sh["seq"]
    kind = sh["kind"]
    tokens = B * (S if kind != "decode" else 1)
    mult = {"train": 6, "prefill": 2, "decode": 2}[kind]
    f = mult * active * tokens

    # attention score+value matmuls (only attn mixers)
    Dh, Hq = cfg.hd, cfg.n_heads
    fa = 0.0
    for i in range(cfg.n_layers):
        spec = cfg.pattern[i % len(cfg.pattern)]
        if spec.mixer != "attn":
            continue
        if kind == "decode":
            skv = min(S, spec.window or S)
            fa += 4 * B * 1 * skv * Hq * Dh
        else:
            skv = min(S, spec.window or S)
            # causal: each query sees ~skv/2 (full) or ~W (local)
            eff = (skv / 2) if spec.window is None else skv
            fa += 4 * B * S * eff * Hq * Dh
    fa *= 3 if kind == "train" else 1
    return (f + fa) / CHIPS


def build_table(dryrun_dir="artifacts/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*__single.json"))):
        r = json.load(open(path))
        if r.get("status") != "ok" or "metered" not in r \
                or "total" not in r.get("metered", {}):
            rows.append(dict(arch=r["arch"], shape=r["shape"],
                             status=r.get("status", "?"),
                             reason=r.get("reason", r.get("error", ""))[:60]))
            continue
        tot = r["metered"]["total"]
        t_c = tot["flops"] / PEAK_FLOPS
        t_m = tot["bytes"] / HBM_BW
        t_x = tot["wire"] / LINK_BW
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                  key=lambda kv: kv[1])
        mf = model_flops_analytic(r["arch"], r["shape"])
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], status="ok",
            compute_s=t_c, memory_s=t_m, collective_s=t_x,
            bottleneck=dom[0],
            model_flops=mf, hlo_flops=tot["flops"],
            useful_frac=mf / max(tot["flops"], 1),
            roofline_frac=max(t_c, 1e-30) / max(t_c, t_m, t_x),
            temp_gb=r["memory"]["temp_size_in_bytes"] / 1e9,
            arg_gb=r["memory"]["argument_size_in_bytes"] / 1e9,
        ))
    return rows


def run(csv):
    rows = build_table()
    for r in rows:
        if r["status"] != "ok":
            csv(f"roofline,{r['arch']},{r['shape']},{r['status']},"
                f"{r.get('reason','')}")
            continue
        csv(f"roofline,{r['arch']},{r['shape']},"
            f"compute={r['compute_s']*1e3:.2f}ms,"
            f"memory={r['memory_s']*1e3:.2f}ms,"
            f"collective={r['collective_s']*1e3:.2f}ms,"
            f"bottleneck={r['bottleneck']},"
            f"useful_flops_frac={r['useful_frac']:.2f},"
            f"roofline_frac={r['roofline_frac']:.2f},"
            f"temp={r['temp_gb']:.1f}GB")


if __name__ == "__main__":
    run(print)
