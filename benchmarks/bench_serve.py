"""Serving-engine benchmark: micro-batched auto-dispatch vs naive
per-request dispatch, and warm vs cold lambda cache.

Measures, on a hot-repeat traffic trace:

  * queries/sec and per-micro-batch p50/p99 latency for (a) naive
    per-request dispatch (one backend call per query, B=1) and (b) the
    engine's fixed-shape micro-batching;
  * tile-skip / verified counters for the engine with a cold lambda cache
    vs a warm one -- the warm cache must prune strictly more tiles (its
    caps only ever tighten the running threshold);
  * stacked vs sequential segment sweep over a fanned-out *mutable*
    snapshot of the same workload (p50/p99 + tiles skipped): the
    crossover ``DispatchPolicy.stacked_min_fanout`` encodes, plus the
    engine auto-routing such snapshots to the ``stacked`` route.

The workload (many loose clusters, k well above the leaf occupancy of any
single tile) is chosen so the sweep's running top-k converges over
several tiles; that is the window in which an a-priori cap beats the
self-tightening threshold.  Run:

    PYTHONPATH=src python benchmarks/bench_serve.py
"""
from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from benchmarks.common import (pct, pr4_stacked_query,
                                   quantized_probe_report,
                                   stacked_skip_profile, stacked_vs_seq)
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    from common import (pct, pr4_stacked_query, quantized_probe_report,
                        stacked_skip_profile, stacked_vs_seq)

QUANT_DTYPES = ("bf16", "int8")


def make_workload(n=30000, d=32, n_clusters=64, scale=2.5, n_queries=32,
                  n_hot=4, seed=7):
    """Clustered base data + a trace that repeats ``n_hot`` hot queries.

    This is the *warm-cache* workload: the broad isotropic clusters keep
    the sweep's self-tightening threshold converging slowly, which is
    the window in which the cached a-priori cap prunes strictly more
    tiles (the ``warm > cold`` fence below).  Low-intrinsic-dimension
    data closes that window -- the first tiles already give a
    near-optimal threshold -- so the pruning-power sections use
    :func:`make_planted_workload` instead."""
    rng = np.random.default_rng(seed)
    cents = rng.normal(size=(n_clusters, d)) * scale
    data = (cents[rng.integers(0, n_clusters, n)]
            + rng.normal(size=(n, d))).astype(np.float32)
    hot = rng.normal(size=(n_hot, d + 1)).astype(np.float32)
    trace = np.stack([hot[i % n_hot] for i in range(n_queries)])
    return data, trace


def make_planted_workload(n, d, n_queries=32, n_hot=4, seed=7,
                          kind="planted"):
    """Registered pruning-power workload from the shared dataset
    pipeline.  Default ``kind="planted"`` (clusters in a low-dimensional
    latent subspace): tree pruning is an intrinsic-dimension game, and
    on the isotropic clustered generator the stacked live-skip profile
    bottomed out near noise (~1.3%) -- every probe mode looked the same
    and the probe-width refit had nothing to fit against."""
    from repro.data import make_p2h_dataset

    data, qs = make_p2h_dataset(n, d, kind=kind,
                                n_queries=max(n_hot, 1), seed=seed)
    hot = qs[:n_hot].astype(np.float32)
    trace = np.stack([hot[i % n_hot] for i in range(n_queries)])
    return data, trace


def bench_naive(idx, trace, k):
    """One backend call per request (B=1), paper-style dispatch."""
    idx.query(trace[:1], k=k)  # compile
    lat = []
    t0 = time.perf_counter()
    for q in trace:
        t1 = time.perf_counter()
        idx.query(q[None], k=k, method="dfs")
        lat.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    return {"qps": len(trace) / wall, "p50_ms": pct(lat, 50) * 1e3,
            "p99_ms": pct(lat, 99) * 1e3}


def bench_engine(idx, trace, k, *, use_cache, slot_size=8, passes=2):
    """Micro-batched engine; ``passes`` >= 2 exercises the warm cache."""
    from repro.serve import DispatchPolicy, P2HEngine

    policy = DispatchPolicy(prefer_pallas=False)  # jnp sweep on CPU
    engine = P2HEngine(idx, slot_size=slot_size, policy=policy,
                       use_cache=use_cache)
    engine.query(trace[:slot_size], k=k)  # compile
    per_pass = []
    for _ in range(passes):
        engine.reset_stats()
        t0 = time.perf_counter()
        engine.query(trace, k=k)
        wall = time.perf_counter() - t0
        st = engine.stats()
        sweep = st["counters"].get("sweep", {})
        per_pass.append({
            "qps": len(trace) / wall,
            "p50_ms": st["latency_p50_ms"],
            "p99_ms": st["latency_p99_ms"],
            "routes": st["routes"],
            "tiles_skipped": sweep.get("tiles_skipped", 0),
            "verified": sweep.get("verified", 0),
            # uniform resilience surface: all-zero here (no faults, no
            # supervisor), but the same keys BENCH_resilience.json fences
            "resilience": st["resilience"],
        })
    return per_pass


def bench_stacked(data, trace, k, *, n0=64, fanout=6, iters=10,
                  probe_grid=(0, 2, 4, 8)):
    """Sequential vs stacked segment sweep over a fanned-out mutable
    snapshot of the serving workload, plus the engine's auto-dispatch
    route counts over the same snapshot.

    Modes: the sequential cap-threaded walk, the reconstructed PR-4
    stacked baseline (single pass + host-side per-segment merge), and
    the fused two-pass program at each ``probe_grid`` width plus the
    library default -- the measured crossover ``DispatchPolicy.
    probe_tiles`` is refit against.  ``skip_profile`` reports the
    per-query-granularity *live*-tile skip fractions (the pruning-power
    comparison the probe pass exists to win) and the probe-pass
    overhead."""
    from repro.core.balltree import normalize_query
    from repro.serve import DispatchPolicy, P2HEngine
    from repro.stream import CompactionPolicy, MutableP2HIndex

    chunk = -(-len(data) // fanout)
    m = MutableP2HIndex.from_data(
        data[:chunk], n0=n0,
        policy=CompactionPolicy(delta_capacity=chunk, tombstone_frac=0.95,
                                max_segments=4 * fanout))
    for c in range(1, fanout):  # one delta flush -> one sealed segment
        m.insert_batch(data[c * chunk:(c + 1) * chunk])
        m.compact()
    snap = m.snapshot()
    qn = normalize_query(trace).astype(np.float32)
    res = {"fanout": sum(1 for s in snap.segments if s.live)}
    # probe-mode keys carry a "mode_" prefix so the JSON section
    # ("stacked") can never collide with a mode of the same name --
    # check_bench_json.py validates dotted paths and used to see
    # "stacked.stacked" as ambiguous
    modes = {"mode_seq": {"stacked": False}, "mode_pr4": {"pr4": True}}
    stacked_modes = []
    for p in probe_grid:
        modes[f"mode_p{p}"] = {"stacked": True, "probe_tiles": p}
        stacked_modes.append(f"mode_p{p}")
    modes["mode_stacked"] = {"stacked": True, "probe_tiles": None}
    stacked_modes.append("mode_stacked")
    for dt in QUANT_DTYPES:  # quantized probe at the default width
        modes[f"mode_{dt}"] = {"stacked": True, "probe_tiles": None,
                               "probe_dtype": dt}

    def query_fn(pr4=False, **kw):
        if pr4:
            return pr4_stacked_query(snap, qn, k)
        return snap.query(qn, k, return_counters=True, **kw)[2]

    res.update(stacked_vs_seq(query_fn, modes=modes, iters=iters))
    res["skip_profile"] = stacked_skip_profile(
        snap, qn, k, probe_grid=tuple(probe_grid) + (None,),
        probe_dtypes=QUANT_DTYPES)
    # the quantized-probe acceptance entry: bit-exactness vs the f32
    # launch, the bytes/tile roofline, and the skip/p50 deltas the
    # precision trade costs (slack loosens the probe cap; pass B's f32
    # rescan keeps the answers identical)
    stk = snap.stacked_leaves()
    quant = quantized_probe_report(
        lambda dt: snap.query(qn, k, stacked=True, probe_dtype=dt),
        n0=stk.n0, d=stk.d)
    quant["p50_delta_ms"] = {
        dt: res[f"mode_{dt}"]["p50_ms"] - res["mode_stacked"]["p50_ms"]
        for dt in QUANT_DTYPES}
    quant["skip_delta"] = {
        dt: (res["skip_profile"][f"stacked_{dt}"]["live_skips"]
             - res["skip_profile"]["stacked"]["live_skips"])
        for dt in QUANT_DTYPES}
    res["quantized"] = quant
    # the refit: which probe width wins p50 on this registered config
    res["best_probe_mode"] = min(stacked_modes,
                                 key=lambda m_: res[m_]["p50_ms"])
    engine = P2HEngine(m, policy=DispatchPolicy(prefer_pallas=False))
    engine.query(trace, k=k)
    res["routes"] = engine.stats()["routes"]
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--k", type=int, default=60)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--n0", type=int, default=64)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--kind", default="planted",
                    choices=["normal", "clustered", "planted", "unit",
                             "heavy"],
                    help="dataset family for the stacked pruning-power "
                         "section (registered config: planted)")
    ap.add_argument("--planted-d", type=int, default=16,
                    help="ambient dim for the stacked pruning-power "
                         "section; at d=16 the planted live-skip profile "
                         "reads ~24%% (vs ~3%% at d=32, ~1.3%% on the "
                         "isotropic generator)")
    args = ap.parse_args(argv)

    from repro.core import P2HIndex

    data, trace = make_workload(n=args.n, d=args.d, n_queries=args.queries,
                                seed=args.seed)
    idx = P2HIndex.build(data, n0=args.n0)
    print(f"index: {idx.report.num_leaves} leaves, "
          f"{idx.report.index_bytes / 1e6:.2f} MB, "
          f"built in {idx.report.build_seconds:.2f}s")

    naive = bench_naive(idx, trace, args.k)
    print(f"naive per-request dfs : {naive['qps']:7.1f} q/s   "
          f"p50 {naive['p50_ms']:.1f} ms  p99 {naive['p99_ms']:.1f} ms")

    cold = bench_engine(idx, trace, args.k, use_cache=False)[-1]
    print(f"engine (cold, no cache): {cold['qps']:7.1f} q/s   "
          f"p50 {cold['p50_ms']:.1f} ms  p99 {cold['p99_ms']:.1f} ms  "
          f"routes {cold['routes']}  tiles_skipped {cold['tiles_skipped']}  "
          f"verified {cold['verified']}")

    passes = bench_engine(idx, trace, args.k, use_cache=True, passes=2)
    warm = passes[-1]
    print(f"engine (warm cache)   : {warm['qps']:7.1f} q/s   "
          f"p50 {warm['p50_ms']:.1f} ms  p99 {warm['p99_ms']:.1f} ms  "
          f"routes {warm['routes']}  tiles_skipped {warm['tiles_skipped']}  "
          f"verified {warm['verified']}")

    gain = warm["tiles_skipped"] - cold["tiles_skipped"]
    print(f"warm-cache tile-skip gain: +{gain} tiles "
          f"({cold['tiles_skipped']} -> {warm['tiles_skipped']}), "
          f"verified -{cold['verified'] - warm['verified']}")
    assert warm["tiles_skipped"] > cold["tiles_skipped"], \
        "warm lambda cache must prune strictly more tiles than cold"

    pdata, ptrace = make_planted_workload(args.n, args.planted_d,
                                          n_queries=args.queries,
                                          seed=args.seed, kind=args.kind)
    stacked = bench_stacked(pdata, ptrace, args.k, n0=args.n0)
    stacked["kind"] = args.kind
    seq, stk = stacked["mode_seq"], stacked["mode_stacked"]
    pr4 = stacked["mode_pr4"]
    print(f"mutable snapshot, fan-out {stacked['fanout']}: sequential "
          f"sweep p50 {seq['p50_ms']:.1f} ms p99 {seq['p99_ms']:.1f} ms "
          f"({seq['tiles_skipped']} tiles skipped)  |  PR-4 stacked "
          f"(host merge) p50 {pr4['p50_ms']:.1f} ms  |  two-pass stacked "
          f"p50 {stk['p50_ms']:.1f} ms p99 {stk['p99_ms']:.1f} ms "
          f"({stk['tiles_skipped']} tiles skipped, incl. forced pad/dead "
          f"skips)  ->  {seq['p50_ms'] / max(stk['p50_ms'], 1e-9):.2f}x "
          f"p50 vs sequential, "
          f"{pr4['p50_ms'] / max(stk['p50_ms'], 1e-9):.2f}x vs PR-4 "
          f"baseline; best probe mode {stacked['best_probe_mode']}; "
          f"engine routes {stacked['routes']}")
    prof = stacked["skip_profile"]
    print("live-tile skip fractions (per-query granularity): "
          + "  ".join(f"{m}={r['skip_frac']:.3f}"
                      for m, r in prof.items())
          + f"; probe overhead {prof['stacked']['probe']}")
    quant = stacked["quantized"]
    print("quantized probe: exact=" + str(quant["quantized_exact"])
          + "  " + "  ".join(
              f"{dt}: {quant['bytes_tile_reduction'][dt]:.2f}x bytes/tile "
              f"p50{quant['p50_delta_ms'][dt]:+.2f}ms "
              f"skips{quant['skip_delta'][dt]:+d}"
              for dt in quant["bytes_tile_reduction"]))
    assert quant["quantized_exact"], \
        "quantized probe must stay bit-exact vs the f32 launch"
    from repro.kernels.stacked_sweep import stacked_compile_stats
    cst = stacked_compile_stats()
    return {"naive": naive, "cold": cold, "warm": warm,
            "stacked": stacked, "kind": args.kind,
            "compile_count": cst["compile_count"],
            "cache_hit": cst["cache_hit"]}


def run(csv, *, smoke: bool = False) -> dict:
    """benchmarks.run registry entry point: CSV rows for bench_output
    plus the returned dict ``benchmarks.run`` serializes to
    ``BENCH_serve.json`` (the machine-readable perf trajectory
    successive PRs diff against).

    Uses main()'s defaults: the workload (n, k, clustering) is tuned so
    the warm-cache tile-skip dominance window exists (see module
    docstring) and the closing assert holds.  ``smoke=True`` shrinks the
    workload to a CI-sized config (same shape, same JSON schema)."""
    res = main(["--n", "8000", "--k", "40", "--queries", "16"]
               if smoke else [])
    csv("serve,mode,qps,p50_ms,p99_ms,tiles_skipped,verified")
    for mode in ("naive", "cold", "warm"):
        r = res[mode]
        csv(f"serve,{mode},{r['qps']:.1f},{r['p50_ms']:.3f},"
            f"{r['p99_ms']:.3f},{r.get('tiles_skipped', '')},"
            f"{r.get('verified', '')}")
    stacked = res["stacked"]
    csv("serve_stacked,mode,p50_ms,p99_ms,tiles_skipped,fanout")
    for mode, r in stacked.items():
        if not isinstance(r, dict) or "p50_ms" not in r:
            continue
        csv(f"serve_stacked,{mode},{r['p50_ms']:.3f},{r['p99_ms']:.3f},"
            f"{r['tiles_skipped']},{stacked['fanout']}")
    csv("serve_stacked_skips,mode,live_skips,live_covered,skip_frac")
    for mode, r in stacked["skip_profile"].items():
        csv(f"serve_stacked_skips,{mode},{r['live_skips']},"
            f"{r['live_covered']},{r['skip_frac']:.4f}")
    quant = stacked["quantized"]
    csv("serve_quantized,dtype,exact,bytes_per_tile,bytes_reduction,"
        "p50_delta_ms,skip_delta")
    for dt in quant["exact"]:
        csv(f"serve_quantized,{dt},{quant['exact'][dt]},"
            f"{quant['bytes_per_tile'][dt]},"
            f"{quant['bytes_tile_reduction'][dt]:.3f},"
            f"{quant['p50_delta_ms'][dt]:.3f},{quant['skip_delta'][dt]}")
    return res


if __name__ == "__main__":
    main()
