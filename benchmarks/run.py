"""Benchmark driver: one module per paper table/figure + the roofline
table from the dry-run artifacts.  Prints CSV lines; ``python -m
benchmarks.run`` is the bench_output.txt entry point.

Lanes whose ``run(csv)`` returns a result dict additionally get it
serialized to ``BENCH_<lane>.json`` next to the CSV output (``--out-dir``,
default CWD) -- the machine-readable perf trajectory successive PRs
compare against (today: ``BENCH_serve.json`` with qps / p50 / p99 /
tile-skip / probe-overhead numbers, ``BENCH_stream_sharded.json`` with
the sharded equivalents, ``BENCH_durability.json`` with WAL replay
throughput / recovery latency / the zero-invariant loss counters, and
``BENCH_mesh.json`` with the 1/2/4-device qps/p50/p99 scaling curve, and
``BENCH_resilience.json`` with the read-path chaos fences: no-fault
bit-exactness, degraded-answer oracles, breaker cycles, shed counters).
``--only serve,stream_sharded,durability,mesh,resilience --smoke`` is the
CI bench-smoke entry point: tiny registered configs, same JSON schema,
validated by ``tools/check_bench_json.py``.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time


def _jsonify(obj):
    """Best-effort conversion of bench results (numpy scalars/arrays,
    tuples) into plain JSON types."""
    import numpy as np

    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _jsonify(obj.tolist())
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        obj = float(obj)  # fall through to the NaN/inf check below
    if isinstance(obj, float) and (obj != obj or obj in (np.inf, -np.inf)):
        return None  # NaN/inf have no RFC 8259 spelling -> null
    return obj


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated lane names (e.g. "
                         "'serve,stream_sharded'); default: all lanes")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny registered configs (CI bench-smoke lane); "
                         "only lanes that support it are shrunk")
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_<lane>.json files are written")
    args = ap.parse_args(argv)

    from benchmarks import (bench_ablations, bench_distributed,
                            bench_durability, bench_indexing, bench_kernel,
                            bench_mesh, bench_query, bench_resilience,
                            bench_serve, bench_stream, bench_stream_sharded)

    t0 = time.time()
    emitted = []

    def csv(line: str):
        emitted.append(line)
        print(line, flush=True)

    mods = [
        ("Table III (indexing overhead)", "indexing", bench_indexing),
        ("Figs 5/6 (query time vs recall, k)", "query", bench_query),
        ("Figs 7/8/10/11 (+Thm 5) ablations", "ablations", bench_ablations),
        ("Kernel path", "kernel", bench_kernel),
        ("Distributed lambda exchange", "distributed", bench_distributed),
        ("Serving engine (batching + lambda cache)", "serve", bench_serve),
        ("Streaming index (insert/delete/compaction)", "stream",
         bench_stream),
        ("Sharded streaming index (routed writes, two-round exchange)",
         "stream_sharded", bench_stream_sharded),
        ("Durability (WAL kill-and-recover chaos)", "durability",
         bench_durability),
        ("Multi-device serving mesh (sharded stacked sweep)", "mesh",
         bench_mesh),
        ("Serving resilience (read-path chaos)", "resilience",
         bench_resilience),
    ]
    only = (None if args.only is None
            else {s.strip() for s in args.only.split(",") if s.strip()})
    if only is not None:
        unknown = only - {lane for _, lane, _ in mods}
        if unknown:  # a typo must not look like a clean (empty) pass
            ap.error(f"unknown lane(s) {sorted(unknown)}; known: "
                     f"{sorted(lane for _, lane, _ in mods)} "
                     "(roofline runs only in the full, un-filtered mode)")
    os.makedirs(args.out_dir, exist_ok=True)
    for title, lane, mod in mods:
        if only is not None and lane not in only:
            continue
        print(f"# === {title} ===", flush=True)
        try:
            kw = ({"smoke": True} if args.smoke and "smoke"
                  in inspect.signature(mod.run).parameters else {})
            res = mod.run(csv, **kw)
        except Exception as e:  # keep the suite going; record the failure
            csv(f"ERROR,{mod.__name__},{type(e).__name__}: {e}")
            continue
        if isinstance(res, dict):  # machine-readable perf trajectory
            path = os.path.join(args.out_dir, f"BENCH_{lane}.json")
            with open(path, "w") as f:
                json.dump(_jsonify(res), f, indent=1, sort_keys=True)
            print(f"# wrote {path}", flush=True)
    if only is None:
        print("# === Roofline (from dry-run artifacts) ===", flush=True)
        try:
            from benchmarks import roofline
            roofline.run(csv)
        except Exception as e:
            csv(f"ERROR,roofline,{type(e).__name__}: {e}")
    print(f"# done in {time.time()-t0:.1f}s; {len(emitted)} rows")
    if any(r.startswith("ERROR") for r in emitted):
        sys.exit(1)


if __name__ == "__main__":
    main()
