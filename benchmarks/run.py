"""Benchmark driver: one module per paper table/figure + the roofline
table from the dry-run artifacts.  Prints CSV lines; ``python -m
benchmarks.run`` is the bench_output.txt entry point."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_ablations, bench_distributed,
                            bench_indexing, bench_kernel, bench_query,
                            bench_serve, bench_stream, bench_stream_sharded)

    t0 = time.time()
    emitted = []

    def csv(line: str):
        emitted.append(line)
        print(line, flush=True)

    mods = [
        ("Table III (indexing overhead)", bench_indexing),
        ("Figs 5/6 (query time vs recall, k)", bench_query),
        ("Figs 7/8/10/11 (+Thm 5) ablations", bench_ablations),
        ("Kernel path", bench_kernel),
        ("Distributed lambda exchange", bench_distributed),
        ("Serving engine (batching + lambda cache)", bench_serve),
        ("Streaming index (insert/delete/compaction)", bench_stream),
        ("Sharded streaming index (routed writes, two-round exchange)",
         bench_stream_sharded),
    ]
    for title, mod in mods:
        print(f"# === {title} ===", flush=True)
        try:
            mod.run(csv)
        except Exception as e:  # keep the suite going; record the failure
            csv(f"ERROR,{mod.__name__},{type(e).__name__}: {e}")
    print("# === Roofline (from dry-run artifacts) ===", flush=True)
    try:
        from benchmarks import roofline
        roofline.run(csv)
    except Exception as e:
        csv(f"ERROR,roofline,{type(e).__name__}: {e}")
    print(f"# done in {time.time()-t0:.1f}s; {len(emitted)} rows")
    if any(r.startswith("ERROR") for r in emitted):
        sys.exit(1)


if __name__ == "__main__":
    main()
