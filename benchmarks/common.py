"""Shared benchmark helpers: datasets, recall, timing."""
from __future__ import annotations

import time

import numpy as np

from repro.core import exact_search
from repro.core.balltree import append_ones, normalize_query
from repro.data import make_p2h_dataset

# container-scale stand-ins for the paper's dataset grid (Table II):
# name -> (n, d, kind). Kinds span the paper's regimes: clustered image-like
# data, isotropic, unit-norm (the pre-NH/FH hashing regime), heavy tails.
DATASETS = {
    "Synth-Normal": (20000, 32, "normal"),
    "Synth-Cluster": (20000, 64, "clustered"),
    "Synth-Unit": (20000, 48, "unit"),
    "Synth-Heavy": (10000, 96, "heavy"),
    # low intrinsic dimension (planted clusters in a latent subspace):
    # the regime where ball/cone bounds actually prune -- streaming
    # live-skip fractions are meaningful here, not ~0
    "Synth-Planted": (20000, 64, "planted"),
}
N_QUERIES = 20


def load(name, seed=0):
    n, d, kind = DATASETS[name]
    x, q = make_p2h_dataset(n, d, kind=kind, n_queries=N_QUERIES, seed=seed)
    return x, normalize_query(q)


def ground_truth(x, q, k):
    import jax.numpy as jnp

    d, i = exact_search(jnp.asarray(append_ones(x)), jnp.asarray(q), k=k)
    return np.asarray(d), np.asarray(i)


def recall(ids, gt_ids):
    hits = sum(len(set(a.tolist()) & set(b.tolist()))
               for a, b in zip(ids, gt_ids))
    return hits / gt_ids.size


def pct(xs, p):
    """Nearest-rank percentile of a list of samples (nan when empty)."""
    if not xs:
        return float("nan")
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(round(p / 100 * (len(xs) - 1))))]


def timeit(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # warmup/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def stacked_vs_seq(query_fn, *, iters=20, modes=None):
    """Sweep-schedule timing harness shared by bench_serve and
    bench_stream_sharded.  ``query_fn(**mode_kwargs)`` runs one query
    batch and returns the (8,) search counters; the first call per mode
    doubles as compile warmup, then the timed iterations alternate modes
    so machine noise hits all equally.  ``modes`` is an ordered ``{name:
    kwargs}`` mapping (default: the classic ``seq`` / ``stacked`` pair);
    returns ``{mode: {"p50_ms", "p99_ms", "tiles_skipped"}}`` (stacked
    skip counts include the force-skipped pad/dead tiles of the common
    grid -- see :func:`stacked_skip_profile` for the live-tile view)."""
    if modes is None:
        modes = {"seq": {"stacked": False}, "stacked": {"stacked": True}}
    skips = {m: int(np.asarray(query_fn(**kw))[7])
             for m, kw in modes.items()}
    lat = {m: [] for m in modes}
    for _ in range(iters):
        for m, kw in modes.items():
            t0 = time.perf_counter()
            query_fn(**kw)
            lat[m].append(time.perf_counter() - t0)
    return {m: {"p50_ms": pct(lat[m], 50) * 1e3,
                "p99_ms": pct(lat[m], 99) * 1e3,
                "tiles_skipped": skips[m]}
            for m in modes}


def live_tiles_covered(segments, n_queries: int) -> int:
    """Per-query-granularity live-tile coverage denominator shared by
    the serve and sharded skip profiles (tiles holding >= 1 live point,
    judged on the segments' current ids planes)."""
    from repro.kernels.stacked_sweep import _segment_live_tiles

    return n_queries * sum(_segment_live_tiles(s) for s in segments
                           if s.live)


def stacked_live_skip_entry(stk, qn, k, *, cap, probe, covered, is_bc,
                            extra_d=None, extra_i=None, probe_dtype=None):
    """One skip-profile row: run the two-pass program at per-query
    granularity (bq=1) and account its live-tile skips (forced pad/dead
    skips excluded).  Shared by the serve-side and sharded-round-2
    profiles so both acceptance comparisons use one accounting.
    ``probe_dtype`` selects the probe pass's precision (None = f32) --
    the quantized rows of the profile report how much live-tile pruning
    the widened (slack-loosened) probe cap gives back."""
    import jax.numpy as jnp

    from repro.kernels.stacked_sweep import stacked_sweep_query

    _, _, cnt, info = stacked_sweep_query(
        stk, jnp.asarray(qn), k, bq=1, lambda_cap=cap, probe_tiles=probe,
        extra_d=extra_d, extra_i=extra_i, use_ball=is_bc, use_cone=is_bc,
        probe_dtype=probe_dtype)
    live_skips = int(np.asarray(info["seg_skips"]).sum()
                     - np.asarray(info["forced_skips"]).sum())
    return {"live_skips": live_skips, "live_covered": covered,
            "skip_frac": live_skips / max(1, covered),
            "probe": info["probe"]}


def pr4_stacked_query(snap, qn, k):
    """The pre-fusion (PR-4) stacked route, reconstructed for baseline
    timing: single-pass planes sweep under the entry cap + *host-side*
    per-segment merge -- exactly the schedule the two-pass in-launch
    program replaces.  Returns the (8,) counters (results materialized
    so timing includes the device sync)."""
    import jax.numpy as jnp

    from repro.core import search
    from repro.kernels.stacked_sweep import stacked_sweep_search

    bd, bi, _ = snap.delta_candidates(jnp.asarray(qn), k)
    B = qn.shape[0]
    sd, sg, cnt, _ = stacked_sweep_search(
        snap.stacked_leaves(), jnp.asarray(qn), k,
        lambda_cap=bd[:, k - 1], probe_tiles=0,
        use_ball=snap.variant == "bc", use_cone=snap.variant == "bc")
    N = sd.shape[0]
    fd, fi = search.merge_topk(
        jnp.concatenate([bd, jnp.moveaxis(sd, 0, 1).reshape(B, N * k)],
                        axis=1),
        jnp.concatenate([bi, jnp.moveaxis(sg, 0, 1).reshape(B, N * k)],
                        axis=1), k)
    np.asarray(fd), np.asarray(fi)
    return cnt


def stacked_skip_profile(snap, qn, k, *, probe_grid=(0, None),
                         probe_dtypes=()):
    """Live-tile skip accounting at per-query granularity (bq=1): the
    sequential cap-threaded walk vs the two-pass stacked sweep at each
    probe setting, on one pinned snapshot.

    Skip *fractions* are live skips over live tiles covered, so the
    stacked grid's force-skipped pad/dead tiles -- which pay for
    themselves structurally -- are excluded: this is the apples-to-
    apples pruning-power comparison the probe pass exists to win.
    Returns ``{"seq": {...}, "stacked_p<p>": {...}, "stacked": {...}}``
    (the unlabeled ``stacked`` entry is the library-default probe);
    each dtype in ``probe_dtypes`` adds a ``stacked_<dtype>`` row at the
    default probe width -- the quantized-vs-f32 skip comparison."""
    import jax.numpy as jnp

    _, _, seq_cnt = snap.query(qn, k, stacked=False, return_counters=True)
    covered = live_tiles_covered(snap.segments, qn.shape[0])
    out = {"seq": {
        "live_skips": int(np.asarray(seq_cnt)[7]),
        "live_covered": covered,
        "skip_frac": int(np.asarray(seq_cnt)[7]) / max(1, covered),
    }}
    bd, bi, _ = snap.delta_candidates(jnp.asarray(qn), k)
    stk = snap.stacked_leaves()
    is_bc = snap.variant == "bc"
    for p in probe_grid:
        name = "stacked" if p is None else f"stacked_p{p}"
        out[name] = stacked_live_skip_entry(
            stk, qn, k, cap=bd[:, k - 1], probe=p, covered=covered,
            is_bc=is_bc, extra_d=bd, extra_i=bi)
    for dt in probe_dtypes:
        out[f"stacked_{dt}"] = stacked_live_skip_entry(
            stk, qn, k, cap=bd[:, k - 1], probe=None, covered=covered,
            is_bc=is_bc, extra_d=bd, extra_i=bi, probe_dtype=dt)
    return out


def quantized_probe_report(query_fn, *, n0, d, dtypes=("bf16", "int8")):
    """Quantized-probe acceptance entry shared by bench_serve and
    bench_stream_sharded.  ``query_fn(probe_dtype)`` runs one query
    batch through the serving route and returns ``(dists, ids)``; the
    report pins the exactness contract (``quantized_exact``: every
    quantized dtype's final answers BIT-identical to the all-f32
    launch) and the probe's bytes/tile roofline (``bytes_per_tile`` /
    ``bytes_tile_reduction`` vs f32 -- the bandwidth the low-precision
    plane saves, the acceptance floor on bf16 is 1.8x)."""
    from repro.kernels.stacked_sweep import probe_bytes_per_tile

    fd0, fi0 = (np.asarray(a) for a in query_fn("f32"))
    f32_bytes = probe_bytes_per_tile("f32", n0, d)
    rep = {"bytes_per_tile": {"f32": f32_bytes},
           "bytes_tile_reduction": {}, "exact": {}}
    ok = True
    for dt in dtypes:
        fd, fi = (np.asarray(a) for a in query_fn(dt))
        exact = bool(np.array_equal(fd, fd0) and np.array_equal(fi, fi0))
        rep["exact"][dt] = exact
        ok = ok and exact
        b = probe_bytes_per_tile(dt, n0, d)
        rep["bytes_per_tile"][dt] = b
        rep["bytes_tile_reduction"][dt] = f32_bytes / b
    rep["quantized_exact"] = ok
    return rep
