"""Shared benchmark helpers: datasets, recall, timing."""
from __future__ import annotations

import time

import numpy as np

from repro.core import exact_search
from repro.core.balltree import append_ones, normalize_query
from repro.data import make_p2h_dataset

# container-scale stand-ins for the paper's dataset grid (Table II):
# name -> (n, d, kind). Kinds span the paper's regimes: clustered image-like
# data, isotropic, unit-norm (the pre-NH/FH hashing regime), heavy tails.
DATASETS = {
    "Synth-Normal": (20000, 32, "normal"),
    "Synth-Cluster": (20000, 64, "clustered"),
    "Synth-Unit": (20000, 48, "unit"),
    "Synth-Heavy": (10000, 96, "heavy"),
}
N_QUERIES = 20


def load(name, seed=0):
    n, d, kind = DATASETS[name]
    x, q = make_p2h_dataset(n, d, kind=kind, n_queries=N_QUERIES, seed=seed)
    return x, normalize_query(q)


def ground_truth(x, q, k):
    import jax.numpy as jnp

    d, i = exact_search(jnp.asarray(append_ones(x)), jnp.asarray(q), k=k)
    return np.asarray(d), np.asarray(i)


def recall(ids, gt_ids):
    hits = sum(len(set(a.tolist()) & set(b.tolist()))
               for a, b in zip(ids, gt_ids))
    return hits / gt_ids.size


def pct(xs, p):
    """Nearest-rank percentile of a list of samples (nan when empty)."""
    if not xs:
        return float("nan")
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(round(p / 100 * (len(xs) - 1))))]


def timeit(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # warmup/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def stacked_vs_seq(query_fn, *, iters=20):
    """Stacked-vs-sequential sweep timing harness shared by bench_serve
    and bench_stream_sharded.  ``query_fn(stacked: bool)`` runs one
    query batch and returns the (8,) search counters; the first call per
    mode doubles as compile warmup, then the timed iterations alternate
    modes so machine noise hits both equally.  Returns ``{mode:
    {"p50_ms", "p99_ms", "tiles_skipped"}}`` for modes ``seq`` /
    ``stacked`` (stacked skip counts include the force-skipped pad/dead
    tiles of the common grid)."""
    modes = (("seq", False), ("stacked", True))
    skips = {mode: int(np.asarray(query_fn(flag))[7])
             for mode, flag in modes}
    lat = {mode: [] for mode, _ in modes}
    for _ in range(iters):
        for mode, flag in modes:
            t0 = time.perf_counter()
            query_fn(flag)
            lat[mode].append(time.perf_counter() - t0)
    return {mode: {"p50_ms": pct(lat[mode], 50) * 1e3,
                   "p99_ms": pct(lat[mode], 99) * 1e3,
                   "tiles_skipped": skips[mode]}
            for mode, _ in modes}
