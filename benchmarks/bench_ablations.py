"""Paper Figures 7/8/10/11: branch preference, individual lower bounds,
time profile, leaf-size sensitivity."""
from __future__ import annotations

from repro.core.api import P2HIndex

from benchmarks.common import ground_truth, load, timeit


def run(csv):
    x, q = load("Synth-Cluster")
    k = 10
    gtd, gti = ground_truth(x, q, k)

    # --- Fig 7: center vs lower-bound branch preference (DFS) ---
    bc = P2HIndex.build(x, n0=128, variant="bc")
    for branch in ("center", "bound"):
        t, (bd, bi, st) = timeit(bc.query, q, k, method="dfs", branch=branch,
                                 normalize=False, return_stats=True)
        csv(f"branch_pref,{branch},{t/len(q)*1e3:.3f}ms,"
            f"nodes={st['nodes_visited']},verified={st['verified']}")

    # --- Fig 8: individual point-level bounds ---
    variants = {
        "bc": dict(use_ball=True, use_cone=True),
        "bc-wo-C": dict(use_ball=True, use_cone=False),
        "bc-wo-B": dict(use_ball=False, use_cone=True),
        "bc-wo-BC": dict(use_ball=False, use_cone=False),
    }
    for vname, kw in variants.items():
        t, (bd, bi, st) = timeit(bc.query, q, k, method="dfs",
                                 normalize=False, return_stats=True, **kw)
        csv(f"bounds,{vname},{t/len(q)*1e3:.3f}ms,"
            f"verified={st['verified']},ball_pruned={st['ball_pruned']},"
            f"cone_pruned={st['cone_pruned']}")

    # --- Fig 10: time-profile proxy (counter breakdown) ---
    _, (bd, bi, st) = timeit(bc.query, q, k, method="dfs", normalize=False,
                             return_stats=True)
    csv(f"profile,bc,ip_ops={st['ip_ops']},verified={st['verified']},"
        f"leaves={st['leaves_scanned']},pruned_nodes={st['nodes_pruned']}")
    ball = P2HIndex.build(x, n0=128, variant="ball")
    _, (bd2, bi2, st2) = timeit(ball.query, q, k, method="dfs",
                                normalize=False, return_stats=True)
    csv(f"profile,ball,ip_ops={st2['ip_ops']},verified={st2['verified']},"
        f"leaves={st2['leaves_scanned']},pruned_nodes={st2['nodes_pruned']}")

    # --- Fig 11: leaf size sweep ---
    for n0 in (64, 128, 256, 512):
        idx = P2HIndex.build(x, n0=n0, variant="bc")
        t, (bd, bi, st) = timeit(idx.query, q, k, method="dfs",
                                 normalize=False, return_stats=True)
        csv(f"leaf_size,N0={n0},{t/len(q)*1e3:.3f}ms,"
            f"verified={st['verified']}")

    # --- Theorem 5: collaborative inner-product computing ---
    for collab in (True, False):
        _, (bd, bi, st) = timeit(bc.query, q, k, method="dfs",
                                 use_collab=collab, normalize=False,
                                 return_stats=True)
        csv(f"collab_ip,{'on' if collab else 'off'},ip_ops={st['ip_ops']}")
