"""Read-path resilience benchmark: the chaos-lane fences for the
serving engine's failure-domain layer.

Four sections, each a correctness claim first and a latency number
second (smoke configs shrink the numbers, never the claims):

  * ``nofault`` -- the zero-overhead invariant: with no faults injected,
    the resilient exchange answers **bit-identically** to the plain
    two-round exchange (``exact``), degrades nothing (``missing`` = 0),
    and its p50 overhead is reported (supervised calls add thread
    hand-offs, not algorithm changes);
  * ``straggler`` -- one shard hangs on every call: every query must
    return a *degraded* answer before its deadline (``p99_bounded``,
    ``deadline_violations`` = 0), the answer must be exactly the oracle
    over the live shards (``degraded_exact_live``), and the loss must be
    reported (``complete_false``, ``missing_shards``);
  * ``breaker`` -- a shard errors through a bounded window, then heals:
    the per-shard circuit breaker must trip (fast-failing follow-up
    queries, sparing the backend), half-open probe, and close again
    (``cycle_ok`` = tripped AND recovered AND final answer complete);
  * ``shed`` -- admission control under overload: queue-depth
    rejections, exhausted-budget rejections at submit, and
    expired-in-queue batches shed at execute with inf results instead
    of an exception (``observed`` = all three counters fired).

Run:

    PYTHONPATH=src python benchmarks/bench_resilience.py
"""
from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from benchmarks.common import pct
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    from common import pct


def _live_oracle(snaps, qn, k):
    import jax.numpy as jnp

    from repro.core import exact_search

    Xs, Gs = [], []
    for sn in snaps:
        X, G = sn.live_points()
        if len(X):
            Xs.append(X)
            Gs.append(G)
    if not Xs:
        B = qn.shape[0]
        return (np.full((B, k), np.inf, np.float32),
                np.full((B, k), -1, np.int32))
    X, G = np.concatenate(Xs), np.concatenate(Gs)
    ed, ei = exact_search(jnp.asarray(X), jnp.asarray(qn), k=k)
    ed, ei = np.asarray(ed), np.asarray(ei)
    return ed, np.where(ei >= 0, G[np.clip(ei, 0, len(G) - 1)], -1)


def bench_nofault(m, q, k, *, iters):
    """Zero-overhead invariant: plain vs resilient, no faults."""
    from repro.serve.resilience import ResilienceConfig, ShardSupervisor

    sup = ShardSupervisor(ResilienceConfig(shard_timeout_s=60.0))
    m.query(q, k=k, method="sweep")                    # warm plain
    m.query(q, k=k, method="sweep", resilience=sup)    # warm resilient
    plain_lat, res_lat, exact, missing = [], [], True, 0
    for _ in range(iters):
        t0 = time.perf_counter()
        bd0, bi0 = m.query(q, k=k, method="sweep")
        plain_lat.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        bd1, bi1, info = m.query(q, k=k, method="sweep",
                                 return_info=True, resilience=sup)
        res_lat.append(time.perf_counter() - t0)
        exact = exact and bool(np.array_equal(bd0, bd1)
                               and np.array_equal(bi0, bi1))
        missing += len(info["missing_shards"])
    p50_plain = pct(plain_lat, 50) * 1e3
    p50_res = pct(res_lat, 50) * 1e3
    return {
        "iters": iters,
        "p50_plain_ms": p50_plain,
        "p50_resilient_ms": p50_res,
        "overhead_frac": (p50_res - p50_plain) / max(p50_plain, 1e-9),
        "exact": exact,
        "missing": missing,
        "supervisor": sup.stats(),
    }


def bench_straggler(m, q, k, *, iters, shard_timeout_s, deadline_s):
    """One shard hangs on every call: degraded answers, on time."""
    from repro.runtime.fault_tolerance import RetryPolicy
    from repro.core.balltree import normalize_query
    from repro.serve.resilience import (FaultInjector, FaultSpec,
                                        ResilienceConfig, ShardSupervisor)

    m.query(q, k=k, method="sweep")  # warm every per-shard program
    snaps = [sh.snapshot() for sh in m.shards]
    qn = normalize_query(q).astype(np.float32)
    inj = FaultInjector({0: [FaultSpec("hang")]}, hang_s=60.0)
    sup = ShardSupervisor(ResilienceConfig(
        shard_timeout_s=shard_timeout_s, fault_injector=inj,
        retry=RetryPolicy(max_restarts=0)))
    lat, violations, exact_live, complete_false = [], 0, True, True
    missing_seen = set()
    for _ in range(iters):
        t0 = time.perf_counter()
        bd, bi, info = m.query(q, k=k, method="sweep", return_info=True,
                               resilience=sup, deadline_s=deadline_s)
        dt = time.perf_counter() - t0
        lat.append(dt)
        if dt > deadline_s:
            violations += 1
        missing_seen.update(info["missing_shards"])
        complete_false = complete_false and not info["complete"]
        live = [snaps[si] for si in range(len(snaps))
                if si not in info["missing_shards"]]
        ed, _ = _live_oracle(live, qn, k)
        exact_live = exact_live and bool(
            np.allclose(bd, ed, rtol=1e-4, atol=1e-5))
    inj.release()
    time.sleep(0.2)  # drain abandoned workers
    p99 = pct(lat, 99)
    return {
        "queries": iters,
        "deadline_s": deadline_s,
        "shard_timeout_s": shard_timeout_s,
        "p50_ms": pct(lat, 50) * 1e3,
        "p99_ms": p99 * 1e3,
        "p99_bounded": bool(p99 <= deadline_s),
        "deadline_violations": violations,
        "degraded_exact_live": exact_live,
        "complete_false": complete_false,
        "missing_shards": sorted(missing_seen),
        "supervisor": sup.stats(),
    }


def bench_breaker(m, q, k, *, error_window=4, reset_s=0.2, max_rounds=12):
    """Trip -> fast-fail -> half-open probe -> recover, end to end."""
    from repro.runtime.fault_tolerance import RetryPolicy
    from repro.serve.resilience import (FaultInjector, FaultSpec,
                                        ResilienceConfig, ShardSupervisor)

    m.query(q, k=k, method="sweep")  # warm
    inj = FaultInjector({1: [FaultSpec("error", until=error_window)]})
    sup = ShardSupervisor(ResilienceConfig(
        shard_timeout_s=60.0, breaker_failures=2, breaker_reset_s=reset_s,
        fault_injector=inj, retry=RetryPolicy(max_restarts=0)))
    rounds, healed = 0, False
    degraded_rounds = 0
    t0 = time.perf_counter()
    for rounds in range(1, max_rounds + 1):
        _, _, info = m.query(q, k=k, method="sweep", return_info=True,
                             resilience=sup)
        if info["missing_shards"]:
            degraded_rounds += 1
            time.sleep(reset_s + 0.05)  # let the breaker reach half-open
        else:
            healed = bool(info["complete"])
            break
    st = sup.stats()
    return {
        "rounds": rounds,
        "degraded_rounds": degraded_rounds,
        "heal_s": time.perf_counter() - t0,
        "trips": st["breaker_trips"],
        "recoveries": st["breaker_recoveries"],
        "open_skips": st["breaker_open_skips"],
        "cycle_ok": bool(st["breaker_trips"] >= 1
                         and st["breaker_recoveries"] >= 1 and healed),
        "supervisor": st,
    }


def bench_shed(m, q, k, *, burst=8, max_pending=2):
    """Admission control: queue-depth + budget shedding, expired-batch
    shed at execute."""
    from repro.serve import P2HEngine
    from repro.serve.resilience import QueryRejected, ResilienceConfig

    eng = P2HEngine(m, slot_size=4,
                    resilience=ResilienceConfig(shard_timeout_s=60.0,
                                                max_pending=max_pending))
    eng.query(q[:4], k=k)  # warm the engine route
    admitted = rejected = 0
    for i in range(burst):
        try:
            eng.submit(q[i % len(q)], k=k)
            admitted += 1
        except QueryRejected:
            rejected += 1
    eng.flush()
    try:
        eng.submit(q[0], k=k, deadline_s=0.0)
    except QueryRejected:
        pass
    # a batch whose budget dies in the queue is shed at execute
    t_exp = eng.submit(q[0], k=k, deadline_s=0.005)
    time.sleep(0.02)
    eng.flush()
    meta = eng.result_meta(t_exp)
    bd, _ = eng.result(t_exp)
    st = eng.stats()["resilience"]
    return {
        "burst": burst,
        "max_pending": max_pending,
        "admitted": admitted,
        "queue_full": st["shed_queue_full"],
        "deadline": st["shed_deadline"],
        "expired_batches": st["shed_expired_batches"],
        "expired_shed_inf": bool(np.all(np.isinf(bd)) and meta["shed"]),
        "observed": bool(st["shed_queue_full"] > 0
                         and st["shed_deadline"] > 0
                         and st["shed_expired_batches"] > 0),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=9000)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--n0", type=int, default=64)
    ap.add_argument("--shards", type=int, default=3)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--straggler-iters", type=int, default=8)
    ap.add_argument("--shard-timeout-s", type=float, default=0.15)
    ap.add_argument("--deadline-s", type=float, default=2.0)
    ap.add_argument("--kind", default="planted",
                    choices=["normal", "clustered", "planted", "unit",
                             "heavy"])
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)

    from repro.data import make_p2h_dataset
    from repro.stream import CompactionPolicy, ShardedMutableP2HIndex

    data, q = make_p2h_dataset(args.n, args.d, kind=args.kind,
                               n_queries=args.queries, seed=args.seed)
    m = ShardedMutableP2HIndex.from_data(
        data, args.shards, n0=args.n0,
        policy=CompactionPolicy(delta_capacity=64))

    nofault = bench_nofault(m, q, args.k, iters=args.iters)
    print(f"nofault: plain p50 {nofault['p50_plain_ms']:.2f} ms, "
          f"resilient p50 {nofault['p50_resilient_ms']:.2f} ms "
          f"({nofault['overhead_frac']:+.1%}); bit-exact="
          f"{nofault['exact']}, missing={nofault['missing']}")
    assert nofault["exact"], \
        "no-fault resilient exchange must be bit-exact vs the plain path"

    straggler = bench_straggler(
        m, q, args.k, iters=args.straggler_iters,
        shard_timeout_s=args.shard_timeout_s, deadline_s=args.deadline_s)
    print(f"straggler: p50 {straggler['p50_ms']:.0f} ms, "
          f"p99 {straggler['p99_ms']:.0f} ms vs deadline "
          f"{straggler['deadline_s']*1e3:.0f} ms "
          f"(violations={straggler['deadline_violations']}); "
          f"degraded answers exact over live shards="
          f"{straggler['degraded_exact_live']}, missing="
          f"{straggler['missing_shards']}")
    assert straggler["degraded_exact_live"], \
        "degraded answers must equal the oracle over the live shards"

    breaker = bench_breaker(m, q, args.k)
    print(f"breaker: tripped {breaker['trips']}x, "
          f"{breaker['open_skips']} fast-fails while open, recovered "
          f"{breaker['recoveries']}x in {breaker['rounds']} rounds "
          f"({breaker['heal_s']:.2f}s); cycle_ok={breaker['cycle_ok']}")

    shed = bench_shed(m, q, args.k)
    print(f"shed: burst {shed['burst']} -> admitted {shed['admitted']}, "
          f"queue_full={shed['queue_full']}, deadline={shed['deadline']}, "
          f"expired_batches={shed['expired_batches']} "
          f"(inf-result shed={shed['expired_shed_inf']})")

    res = {"nofault": nofault, "straggler": straggler,
           "breaker": breaker, "shed": shed,
           "shards": args.shards, "n": args.n, "kind": args.kind}
    m.close()
    return res


def run(csv, *, smoke: bool = False) -> dict:
    """benchmarks.run registry entry point: CSV rows for bench_output
    plus the returned dict serialized to ``BENCH_resilience.json``.
    ``smoke=True`` shrinks the workload to a CI-sized config (same
    shape, same JSON schema -- and the same correctness fences: the
    exactness/boundedness claims are config-independent)."""
    res = main(["--n", "2500", "--iters", "8", "--straggler-iters", "4",
                "--deadline-s", "3.0"] if smoke else [])
    csv("resilience,section,metric,value")
    for section in ("nofault", "straggler", "breaker", "shed"):
        for key, val in res[section].items():
            if isinstance(val, (bool, int, float)):
                csv(f"resilience,{section},{key},{val}")
    return res


if __name__ == "__main__":
    main()
